"""Paper Figures 5-10: approximate KPCA.

- misalignment (Eq. 10) of the top-k approximate eigenvectors vs exact,
  against both c (memory) and wall-time (Figs 5/6);
- with --knn: KPCA features + 10-NN generalization error (Figs 7-10).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (calibrate_sigma, knn_classify, make_dataset,
                               print_table)
from repro.core import eig, spsd
from repro.core.kernelop import RBFKernel


def _methods(Kop, key, c, s_mults=(2, 4, 8)):
    base = spsd.sample_C(Kop, key, c)
    out = {}
    t0 = time.perf_counter()
    W = Kop.block(base.P_indices, base.P_indices)
    U = spsd.nystrom_U(W)
    out["nystrom"] = (base.C, U, time.perf_counter() - t0)
    for m in s_mults:
        t0 = time.perf_counter()
        ap = spsd.fast_model_from_C(Kop, base.C, jax.random.fold_in(key, m),
                                    m * c, P_indices=base.P_indices,
                                    s_sketch="uniform")
        out[f"fast s={m}c"] = (ap.C, ap.U, time.perf_counter() - t0)
    t0 = time.perf_counter()
    proto = spsd.prototype_model(Kop, base.C, base.P_indices)
    out["prototype"] = (proto.C, proto.U, time.perf_counter() - t0)
    return out


def run_misalignment(dataset: str, k: int = 3, cs=(16, 32, 64), seed=0):
    X, _ = make_dataset(dataset, seed=seed)
    sigma = calibrate_sigma(X, 0.9, k)
    Kop = RBFKernel(X, sigma=sigma)
    Kd = Kop.full()
    lam, V = jnp.linalg.eigh(Kd)
    U_true = V[:, ::-1][:, :k]

    rows = []
    for c in cs:
        for name, (C, U, dt) in _methods(Kop, jax.random.PRNGKey(seed),
                                         c).items():
            res = eig.approx_eigh(C, U, k)
            mis = float(eig.misalignment(U_true, res.eigenvectors))
            rows.append((dataset, c, name, f"{dt * 1e3:8.1f}",
                         f"{mis:.5f}"))
    print_table(f"Fig 5/6: KPCA misalignment ({dataset}, k={k})",
                ["dataset", "c", "method", "U-time ms", "misalignment"],
                rows)
    return rows


def run_knn(dataset: str, k: int = 3, c: int = 48, seed=0):
    X, y = make_dataset(dataset, seed=seed)
    n = X.shape[0]
    ntr = n // 2
    Xtr, ytr, Xte, yte = X[:ntr], y[:ntr], X[ntr:], y[ntr:]
    sigma = calibrate_sigma(Xtr, 0.9, k)
    Kop = RBFKernel(Xtr, sigma=sigma)

    # kernel columns for test points
    d2 = (jnp.sum(Xte ** 2, 1)[None, :] + jnp.sum(Xtr ** 2, 1)[:, None]
          - 2 * Xtr @ Xte.T)
    k_test = jnp.exp(-jnp.maximum(d2, 0) / (2 * sigma ** 2))   # (ntr, nte)

    rows = []
    for name, (C, U, dt) in _methods(Kop, jax.random.PRNGKey(seed),
                                     c).items():
        feats, eres = eig.kpca_features(C, U, k)
        te_feats = eig.kpca_transform(eres, k_test).T           # (nte, k)
        pred = knn_classify(np.asarray(feats), ytr, np.asarray(te_feats))
        err = float(np.mean(pred != np.asarray(yte)))
        rows.append((dataset, name, f"{dt * 1e3:8.1f}", f"{err:.4f}"))
    print_table(f"Fig 7-10: KPCA + 10NN classification ({dataset}, k={k}, "
                f"c={c})", ["dataset", "method", "U-time ms", "test err"],
                rows)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=["pendigit",
                                                     "mushrooms"])
    p.add_argument("--k", type=int, default=3)
    p.add_argument("--knn", action="store_true")
    args = p.parse_args(argv)
    for ds in args.datasets:
        run_misalignment(ds, k=args.k)
        if args.knn:
            run_knn(ds, k=args.k)


if __name__ == "__main__":
    main()
