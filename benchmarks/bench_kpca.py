"""Paper Figures 5-10: approximate KPCA — streaming-native.

- misalignment (Eq. 10) of the top-k approximate eigenvectors against c
  (memory) and wall-time (Figs 5/6);
- with --knn: KPCA features + 10-NN generalization error (Figs 7-10).

Every kernel access streams through the operator protocol: the bandwidth
comes from the calibration registry (one statistic gather), C selection runs
through the ``SelectionPolicy`` registry, the fast U through panel sweeps,
and the *exact-eigvec reference* through randomized subspace iteration
(``eig.streaming_subspace_eigh`` — matmat panel passes).  The n×n kernel is
never materialized: ``full()`` is booby-trapped over this module in
``tests/test_workloads.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import (calibrate_sigma, knn_classify, make_dataset,
                               print_table)
from repro.core import eig, spsd
from repro.core.kernelop import PairwiseKernel
from repro.kernels.pairwise import specs as pw_specs

#: SelectionPolicy names the KPCA/spectral workloads sweep (uniform is the
#: paper's C-selection baseline; adaptive² is the PR-5 accuracy frontier)
SELECTIONS = ("uniform", "leverage", "uniform_adaptive2")


def make_operator(X, sigma=None) -> PairwiseKernel:
    """RBF operator with the registry-calibrated bandwidth (no full())."""
    sigma = calibrate_sigma(X) if sigma is None else sigma
    return PairwiseKernel(X, pw_specs.get_spec("rbf", sigma=float(sigma)))


def reference_eigvecs(Kop, k: int, seed: int = 0) -> eig.EigResult:
    """Exact top-k eigenpairs via streamed subspace iteration (the bench's
    accuracy-vs-dense reference — 10 matmat sweeps, zero densification)."""
    return eig.streaming_subspace_eigh(
        Kop, k, key=jax.random.PRNGKey(seed), power_iters=8)


def _methods(Kop, key, c: int, theta: int = 4, selections=SELECTIONS):
    """(C, U, build-seconds) per method.

    Nyström is the S = P baseline (columns gather + c×c block); each
    ``fast <policy>`` row is Algorithm 1 with that ``SelectionPolicy``
    choosing C and a uniform s = θc sketch for the fast U.
    """
    out = {}
    t0 = time.perf_counter()
    base = spsd.sample_C(Kop, key, c)
    W = Kop.block(base.P_indices, base.P_indices)
    out["nystrom"] = (base.C, spsd.nystrom_U(W), time.perf_counter() - t0)
    for i, sel in enumerate(selections):
        t0 = time.perf_counter()
        ap = spsd.fast_model(Kop, jax.random.fold_in(key, i), c=c,
                             s=theta * c, s_sketch="uniform", selection=sel)
        out[f"fast {sel}"] = (ap.C, ap.U, time.perf_counter() - t0)
    return out


def run_misalignment(dataset: str, k: int = 3, cs=(16, 32, 64), seed=0,
                     n=None, selections=SELECTIONS):
    X, _ = make_dataset(dataset, seed=seed, n=n)
    Kop = make_operator(X)
    U_true = reference_eigvecs(Kop, k, seed).eigenvectors

    rows = []
    for c in cs:
        for name, (C, U, dt) in _methods(Kop, jax.random.PRNGKey(seed), c,
                                         selections=selections).items():
            t0 = time.perf_counter()
            res = eig.approx_eigh(C, U, k)
            res.eigenvectors.block_until_ready()
            mis = float(eig.misalignment(U_true, res.eigenvectors))
            rows.append({"dataset": dataset, "n": int(X.shape[0]), "c": c,
                         "k": k, "method": name,
                         "seconds": dt + time.perf_counter() - t0,
                         "misalignment": mis})
    print_table(f"Fig 5/6: KPCA misalignment ({dataset}, k={k})",
                ["dataset", "c", "method", "time ms", "misalignment"],
                [(r["dataset"], r["c"], r["method"],
                  f"{r['seconds'] * 1e3:8.1f}", f"{r['misalignment']:.5f}")
                 for r in rows])
    return rows


def run_knn(dataset: str, k: int = 3, c: int = 48, seed=0, n=None,
            selections=SELECTIONS):
    """KPCA features + 10-NN test error; test-point kernel columns go
    through the serving-path ``cross`` launch (no dense distance matrix)."""
    X, y = make_dataset(dataset, seed=seed, n=n)
    ntr = X.shape[0] // 2
    Xtr, ytr, Xte, yte = X[:ntr], y[:ntr], X[ntr:], y[ntr:]
    Kop = make_operator(Xtr)

    rows = []
    for name, (C, U, dt) in _methods(Kop, jax.random.PRNGKey(seed), c,
                                     selections=selections).items():
        t0 = time.perf_counter()
        feats, eres = eig.kpca_features(C, U, k)
        # K(Xte, Xtr) @ V in one rectangular cross launch, then Λ^{-1/2}
        te_proj = Kop.cross(Xte, (eres.eigenvectors,))[0]       # (nte, k)
        lam = np.maximum(np.asarray(eres.eigenvalues), 1e-12)
        te_feats = np.asarray(te_proj) / np.sqrt(lam)[None, :]
        pred = knn_classify(np.asarray(feats), ytr, te_feats)
        err = float(np.mean(pred != np.asarray(yte)))
        rows.append({"dataset": dataset, "n": int(X.shape[0]), "c": c,
                     "k": k, "method": name,
                     "seconds": dt + time.perf_counter() - t0,
                     "test_err": err})
    print_table(f"Fig 7-10: KPCA + 10NN classification ({dataset}, k={k}, "
                f"c={c})", ["dataset", "method", "time ms", "test err"],
                [(r["dataset"], r["method"], f"{r['seconds'] * 1e3:8.1f}",
                  f"{r['test_err']:.4f}") for r in rows])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--datasets", nargs="*", default=["pendigit",
                                                     "mushrooms"])
    p.add_argument("--k", type=int, default=3)
    p.add_argument("--n", type=int, default=None,
                   help="override dataset size (smoke shapes)")
    p.add_argument("--cs", type=int, nargs="*", default=[16, 32, 64])
    p.add_argument("--knn", action="store_true")
    args = p.parse_args(argv)
    for ds in args.datasets:
        run_misalignment(ds, k=args.k, cs=tuple(args.cs), n=args.n)
        if args.knn:
            run_knn(ds, k=args.k, n=args.n)


if __name__ == "__main__":
    main()
