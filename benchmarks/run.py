"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # CPU-sized defaults
    PYTHONPATH=src python -m benchmarks.run --only cur time
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI pass + JSON artifact
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

SUITES = ["spsd_error", "spsd_error_adaptive", "kpca", "spectral", "cur",
          "time", "landmark", "ablations", "kernels", "serve", "workloads"]

SMOKE_JSON = os.path.join("results", "BENCH_smoke.json")

# The per-PR tracked copy at the repo root: results/BENCH_smoke.json is
# gitignored (CI-artifact only), so every smoke run also refreshes a
# ``BENCH_<tag>.json`` file and commits carry the measured trajectory
# in-tree.  The tag defaults to the short git revision; PRs pass an explicit
# ``--tag prN`` when refreshing the tracked copy they commit.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_tag() -> str:
    """Short git revision of the repo, or 'local' outside a checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, cwd=REPO_ROOT,
                             timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "local"


def tracked_json_path(tag: str) -> str:
    return os.path.join(REPO_ROOT, f"BENCH_{tag}.json")


def smoke(out: str = SMOKE_JSON, tag: str = None) -> int:
    """Tiny-shape pass over every perf entry point, CI-sized (~1 min CPU).

    Exercises the argument plumbing and the streaming code paths so the
    benchmark suite cannot bit-rot, and writes ``results/BENCH_smoke.json``
    (per-step wall time, the fused-vs-separate scaling rows, and the
    per-kernel registry rows) so CI can archive the perf trajectory per PR.
    A tracked ``BENCH_<tag>.json`` copy lands at the repo root (tag from
    ``--tag``, default the short git revision).  Absolute numbers at these
    shapes are noise; trends and the speedup ratio are the signal.
    """
    import jax
    t0 = time.time()
    from benchmarks import bench_cur, bench_kernels, bench_serve, \
        bench_spsd_error, bench_time, bench_workloads
    steps = {}

    def step(name, fn):
        t = time.time()
        out_val = fn()
        steps[name] = round(time.time() - t, 3)
        return out_val

    step("spsd_error_dense",
         lambda: bench_spsd_error.main(["--datasets", "letters", "--n", "400"]))
    step("spsd_error_streaming",
         lambda: bench_spsd_error.main(["--datasets", "letters", "--n", "400",
                                        "--streaming", "--probes", "32"]))
    scaling = step("spsd_error_scaling",
                   lambda: bench_spsd_error.run_scaling([3000]))
    step("time", lambda: bench_time.main(["--ns", "400", "800"]))
    step("time_streaming",
         lambda: bench_time.main(["--ns", "400", "800", "--streaming"]))
    step("cur", lambda: bench_cur.main([]))
    cur_selection = step(
        "cur_streaming_selection",
        lambda: bench_cur.run_streaming_selection(n=800, c=32, sc=64))
    kernels = step("kernels", lambda: bench_kernels.run())
    kernels_bf16 = step("kernels_bf16",
                        lambda: bench_kernels.run(precision="bf16_f32acc"))
    serve = step("serve", lambda: bench_serve.run(loads=(1, 2, 8),
                                                  requests_per_client=6))
    serve_append = step(
        "serve_append",
        lambda: bench_serve.run_append(n=800, batches=4, batch_rows=32))
    workloads = step("workloads", lambda: bench_workloads.run())

    # achieved-vs-roofline per launch, pulled out of the kernel rows so the
    # perf trajectory is one flat section (and one CI artifact) per PR
    roofline = [
        {"kernel": r["kernel"], "precision": r["precision"],
         **r["roofline"]}
        for r in kernels + kernels_bf16 if "roofline" in r]
    l1_routes = {r["precision"]: r["l1_route"]
                 for r in kernels + kernels_bf16
                 if r["kernel"] == "laplacian"}

    payload = {
        "total_seconds": round(time.time() - t0, 3),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "meta": {
            # which tile policies the sweep exercised and which l1dist form
            # the laplacian rows took (mxu_signsplit | vpu_loop)
            "precision_policies": sorted({r["precision"]
                                          for r in kernels + kernels_bf16}),
            "l1dist_route": l1_routes,
            "roofline_profile": roofline[0]["profile"] if roofline else None,
        },
        "steps_seconds": steps,
        "scaling": scaling,
        "kernels": kernels,
        "kernels_bf16": kernels_bf16,
        "roofline": roofline,
        "cur_streaming_selection": cur_selection,
        "serve": serve,
        "serve_append": serve_append,
        "workloads": workloads,
    }
    out_dir = os.path.dirname(out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    # standalone roofline report next to the smoke JSON (CI uploads it as its
    # own artifact so launch-efficiency trends are greppable without the rest
    # of the payload)
    roofline_out = os.path.join(out_dir or ".", "ROOFLINE_smoke.json")
    with open(roofline_out, "w") as f:
        json.dump({"meta": payload["meta"], "roofline": roofline}, f, indent=2)
    tracked = tracked_json_path(tag or default_tag())
    with open(tracked, "w") as f:            # tracked copy at the repo root
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"\nsmoke benchmarks completed in {payload['total_seconds']:.1f}s "
          f"-> {out} (tracked copy: {tracked})")
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", default=None,
                   help=f"subset of {SUITES}")
    p.add_argument("--smoke", action="store_true",
                   help="tiny-shape CI pass over the perf entry points")
    p.add_argument("--smoke-out", default=SMOKE_JSON,
                   help="where --smoke writes its JSON summary")
    p.add_argument("--tag", default=None,
                   help="tag for the tracked repo-root BENCH_<tag>.json copy "
                        "(default: short git revision)")
    args = p.parse_args(argv)
    if args.smoke:
        return smoke(args.smoke_out, tag=args.tag)
    picked = args.only or SUITES

    t0 = time.time()
    if "spsd_error" in picked:
        from benchmarks import bench_spsd_error
        bench_spsd_error.main(["--datasets", "letters", "pendigit",
                               "mushrooms"])
        bench_spsd_error.main(["--datasets", "pendigit", "--eta", "0.99"])
    if "spsd_error_adaptive" in picked:
        from benchmarks import bench_spsd_error
        bench_spsd_error.main(["--datasets", "pendigit", "--adaptive"])
    if "kpca" in picked:
        from benchmarks import bench_kpca
        bench_kpca.main(["--datasets", "pendigit", "mushrooms", "--knn"])
    if "spectral" in picked:
        from benchmarks import bench_spectral
        bench_spectral.main(["--datasets", "pendigit"])
    if "cur" in picked:
        from benchmarks import bench_cur
        bench_cur.main([])
    if "time" in picked:
        from benchmarks import bench_time
        bench_time.main([])
    if "landmark" in picked:
        from benchmarks import bench_landmark_attention
        bench_landmark_attention.main([])
    if "ablations" in picked:
        from benchmarks import bench_ablations
        bench_ablations.main([])
    if "kernels" in picked:
        from benchmarks import bench_kernels
        bench_kernels.main([])
    if "serve" in picked:
        from benchmarks import bench_serve
        bench_serve.main([])
    if "workloads" in picked:
        from benchmarks import bench_workloads
        bench_workloads.main([])
    print(f"\nbenchmarks completed in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
