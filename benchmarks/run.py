"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # CPU-sized defaults
    PYTHONPATH=src python -m benchmarks.run --only cur time
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ["spsd_error", "spsd_error_adaptive", "kpca", "spectral", "cur",
          "time", "landmark", "ablations"]


def smoke() -> int:
    """Tiny-shape pass over every perf entry point, CI-sized (~1 min CPU).

    Exercises the argument plumbing and the streaming code paths so the
    benchmark suite cannot bit-rot; numbers produced here are meaningless.
    """
    t0 = time.time()
    from benchmarks import bench_cur, bench_spsd_error, bench_time
    bench_spsd_error.main(["--datasets", "letters", "--n", "400"])
    bench_spsd_error.main(["--datasets", "letters", "--n", "400",
                           "--streaming", "--probes", "32"])
    bench_spsd_error.main(["--scaling-ns", "3000"])
    bench_time.main(["--ns", "400", "800"])
    bench_time.main(["--ns", "400", "800", "--streaming"])
    bench_cur.main([])
    print(f"\nsmoke benchmarks completed in {time.time() - t0:.1f}s")
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", default=None,
                   help=f"subset of {SUITES}")
    p.add_argument("--smoke", action="store_true",
                   help="tiny-shape CI pass over the perf entry points")
    args = p.parse_args(argv)
    if args.smoke:
        return smoke()
    picked = args.only or SUITES

    t0 = time.time()
    if "spsd_error" in picked:
        from benchmarks import bench_spsd_error
        bench_spsd_error.main(["--datasets", "letters", "pendigit",
                               "mushrooms"])
        bench_spsd_error.main(["--datasets", "pendigit", "--eta", "0.99"])
    if "spsd_error_adaptive" in picked:
        from benchmarks import bench_spsd_error
        bench_spsd_error.main(["--datasets", "pendigit", "--adaptive"])
    if "kpca" in picked:
        from benchmarks import bench_kpca
        bench_kpca.main(["--datasets", "pendigit", "mushrooms", "--knn"])
    if "spectral" in picked:
        from benchmarks import bench_spectral
        bench_spectral.main(["--datasets", "pendigit"])
    if "cur" in picked:
        from benchmarks import bench_cur
        bench_cur.main([])
    if "time" in picked:
        from benchmarks import bench_time
        bench_time.main([])
    if "landmark" in picked:
        from benchmarks import bench_landmark_attention
        bench_landmark_attention.main([])
    if "ablations" in picked:
        from benchmarks import bench_ablations
        bench_ablations.main([])
    print(f"\nbenchmarks completed in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
