"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # CPU-sized defaults
    PYTHONPATH=src python -m benchmarks.run --only cur time
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ["spsd_error", "spsd_error_adaptive", "kpca", "spectral", "cur",
          "time", "landmark", "ablations"]


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", default=None,
                   help=f"subset of {SUITES}")
    args = p.parse_args(argv)
    picked = args.only or SUITES

    t0 = time.time()
    if "spsd_error" in picked:
        from benchmarks import bench_spsd_error
        bench_spsd_error.main(["--datasets", "letters", "pendigit",
                               "mushrooms"])
        bench_spsd_error.main(["--datasets", "pendigit", "--eta", "0.99"])
    if "spsd_error_adaptive" in picked:
        from benchmarks import bench_spsd_error
        bench_spsd_error.main(["--datasets", "pendigit", "--adaptive"])
    if "kpca" in picked:
        from benchmarks import bench_kpca
        bench_kpca.main(["--datasets", "pendigit", "mushrooms", "--knn"])
    if "spectral" in picked:
        from benchmarks import bench_spectral
        bench_spectral.main(["--datasets", "pendigit"])
    if "cur" in picked:
        from benchmarks import bench_cur
        bench_cur.main([])
    if "time" in picked:
        from benchmarks import bench_time
        bench_time.main([])
    if "landmark" in picked:
        from benchmarks import bench_landmark_attention
        bench_landmark_attention.main([])
    if "ablations" in picked:
        from benchmarks import bench_ablations
        bench_ablations.main([])
    print(f"\nbenchmarks completed in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
