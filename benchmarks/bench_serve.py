"""Serving latency/throughput bench: p50/p99 per-request latency and
requests/s through ``KernelServer`` at several client-concurrency loads.

    PYTHONPATH=src python -m benchmarks.bench_serve
    PYTHONPATH=src python -m benchmarks.bench_serve --loads 1 4 16
    PYTHONPATH=src python -m benchmarks.bench_serve --append   # n=3000, b=64

Each load runs ``clients`` threads submitting mixed-size KRR/KPCA/feature
queries back-to-back for a fixed request budget; the continuous-batching
loop (``BatchPolicy``) coalesces them into bucketed fused launches.  Rows
land in the smoke-bench payload (``BENCH_<tag>.json``, key ``"serve"``) so
the serving-latency trajectory is tracked per PR alongside the sweep
speedups.  Absolute ms at CI shapes are noise; the signal is p99/p50 shape
(batching fairness) and requests/s trends.

``--append`` benches the incremental maintenance path instead: one full
``build_artifact`` (the rebuild cost a naive corpus-growth strategy pays
per batch) against the per-batch ``append_rows`` absorb (ONE thin b×c
launch + rank-b refresh).  The row lands under ``"serve_append"`` with the
speedup ratio — the ≥5× acceptance at n=3000, b=64 — tracked per PR by
``compare_bench``.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import print_table
from repro.core.instrument import CountingOperator
from repro.kernels.pairwise import specs as pw_specs
from repro.launch.serve_kernel import (
    BatchPolicy,
    KernelServer,
    percentile_ms,
    synth_problem,
)
from repro.serve import build_artifact

QUERY_SIZES = (5, 17, 33, 64)
TASKS_CYCLE = ("krr", "kpca", "features")


def _client(server: KernelServer, queries: List, out_lat: List[float]):
    for Xq, task in queries:
        pending = server.submit(Xq, task)
        pending.wait(timeout=60.0)
        out_lat.append(pending.latency_s)


def run(n: int = 240, d: int = 24, c: int = 48, s: int = 96,
        loads=(1, 4, 16), requests_per_client: int = 8,
        max_wait_ms: float = 2.0, seed: int = 0) -> List[dict]:
    """One row per concurrency load:
    {clients, requests, p50_ms, p99_ms, req_per_s, rows_per_s, buckets,
    cross_sweeps, route}."""
    X, y = synth_problem(n, d, seed)
    spec = pw_specs.get_spec("rbf", sigma=1.0)
    artifact = build_artifact(X, y, spec, c=c, s=s,
                              key=jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed + 7)

    def make_queries(count):
        return [(rng.standard_normal(
                     (int(rng.choice(QUERY_SIZES)), d)).astype(np.float32),
                 TASKS_CYCLE[i % 3]) for i in range(count)]

    rows = []
    for clients in loads:
        op = CountingOperator(artifact.landmark_operator())
        server = KernelServer(
            artifact, BatchPolicy(max_wait_s=max_wait_ms / 1e3), op=op)
        try:
            # warm the jit caches (all bucketed heights compile here)
            for Xq, task in make_queries(6):
                server.submit(Xq, task).wait(timeout=60.0)
            op.reset()
            server.latencies_s.clear()
            buckets0 = server.buckets_served

            per_client = [make_queries(requests_per_client)
                          for _ in range(clients)]
            lats: List[List[float]] = [[] for _ in range(clients)]
            threads = [threading.Thread(target=_client,
                                        args=(server, q, lat))
                       for q, lat in zip(per_client, lats)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
        finally:
            server.stop()

        all_lats = [v for chunk in lats for v in chunk]
        n_req = len(all_lats)
        n_rows = sum(q[0].shape[0] for chunk in per_client for q in chunk)
        rows.append({
            "clients": clients,
            "requests": n_req,
            "p50_ms": round(percentile_ms(all_lats, 50), 3),
            "p99_ms": round(percentile_ms(all_lats, 99), 3),
            "req_per_s": round(n_req / wall, 1),
            "rows_per_s": round(n_rows / wall, 1),
            "buckets": server.buckets_served - buckets0,
            "cross_sweeps": op.counts["cross_sweeps"],
            "route": op.last_route,
        })
    return rows


def run_append(n: int = 3000, d: int = 24, c: int = 48, s: int = 96,
               batches: int = 8, batch_rows: int = 64,
               seed: int = 0) -> List[dict]:
    """One row: full ``build_artifact`` wall-clock vs per-batch
    ``append_rows`` absorb at the same shape.

    {n, batch_rows, batches, build_ms, append_p50_ms, append_p99_ms,
    speedup, rows_per_s, append_sweeps, drift} — ``speedup`` is
    build_ms / append_p50_ms, the factor the incremental path saves over
    rebuilding to absorb one batch (the ≥5× acceptance at n=3000, b=64).
    """
    from repro.serve import append_rows, init_state

    X, y = synth_problem(n, d, seed)
    spec = pw_specs.get_spec("rbf", sigma=1.0)

    # a throwaway build at a smaller n warms the sweep/selection jit caches
    # so build_ms times the real work, not compilation
    Xw, yw = synth_problem(max(2 * c, 128), d, seed + 1)
    build_artifact(Xw, yw, spec, c=c, s=s, key=jax.random.PRNGKey(seed))
    t0 = time.perf_counter()
    artifact = build_artifact(X, y, spec, c=c, s=s,
                              key=jax.random.PRNGKey(seed))
    jax.block_until_ready(artifact.C)
    build_ms = (time.perf_counter() - t0) * 1e3

    state = init_state(artifact, np.asarray(y))
    op = CountingOperator(artifact.landmark_operator())
    rng = np.random.default_rng(seed + 3)

    def batch():
        Xb = rng.standard_normal((batch_rows, d)).astype(np.float32)
        yb = rng.standard_normal(batch_rows).astype(np.float32)
        return Xb, yb

    # warm the thin-launch compile, then measure
    artifact, state, _, _ = append_rows(artifact, state, *batch(), op=op)
    op.reset()
    lat_s, stats = [], None
    for _ in range(batches):
        Xb, yb = batch()
        t0 = time.perf_counter()
        artifact, state, stats, _ = append_rows(artifact, state, Xb, yb,
                                                op=op)
        jax.block_until_ready(artifact.heads["krr"])
        lat_s.append(time.perf_counter() - t0)

    p50 = percentile_ms(lat_s, 50)
    return [{
        "n": n, "batch_rows": batch_rows, "batches": batches,
        "build_ms": round(build_ms, 3),
        "append_p50_ms": round(p50, 3),
        "append_p99_ms": round(percentile_ms(lat_s, 99), 3),
        "speedup": round(build_ms / p50, 2),
        "rows_per_s": round(batch_rows * batches / sum(lat_s), 1),
        "append_sweeps": op.counts["append_sweeps"],
        "drift": round(float(stats.drift), 4),
    }]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--n", type=int, default=240)
    p.add_argument("--d", type=int, default=24)
    p.add_argument("--c", type=int, default=48)
    p.add_argument("--s", type=int, default=96)
    p.add_argument("--loads", type=int, nargs="+", default=[1, 4, 16])
    p.add_argument("--requests-per-client", type=int, default=8)
    p.add_argument("--max-wait-ms", type=float, default=2.0)
    p.add_argument("--append", action="store_true",
                   help="bench incremental append_rows vs a full rebuild "
                        "(uses --append-n/--batches/--batch-rows)")
    p.add_argument("--append-n", type=int, default=3000)
    p.add_argument("--batches", type=int, default=8)
    p.add_argument("--batch-rows", type=int, default=64)
    p.add_argument("--min-speedup", type=float, default=None,
                   help="fail unless append speedup >= this (acceptance "
                        "gate: 5x at n=3000, b=64)")
    args = p.parse_args(argv)

    if args.append:
        rows = run_append(n=args.append_n, d=args.d, c=args.c, s=args.s,
                          batches=args.batches, batch_rows=args.batch_rows)
        print_table(
            "incremental append vs full rebuild (append_rows)",
            ["n", "b", "batches", "build_ms", "append_p50_ms", "speedup",
             "rows/s", "append_sweeps", "drift"],
            [[r["n"], r["batch_rows"], r["batches"], r["build_ms"],
              r["append_p50_ms"], r["speedup"], r["rows_per_s"],
              r["append_sweeps"], r["drift"]] for r in rows])
        if args.min_speedup is not None and \
                rows[0]["speedup"] < args.min_speedup:
            print(f"FAIL: append speedup {rows[0]['speedup']}x < "
                  f"required {args.min_speedup}x")
            return 1
        return 0

    rows = run(n=args.n, d=args.d, c=args.c, s=args.s,
               loads=tuple(args.loads),
               requests_per_client=args.requests_per_client,
               max_wait_ms=args.max_wait_ms)
    print_table(
        "serving latency/throughput (KernelServer, continuous batching)",
        ["clients", "requests", "p50_ms", "p99_ms", "req/s", "rows/s",
         "buckets", "route"],
        [[r["clients"], r["requests"], r["p50_ms"], r["p99_ms"],
          r["req_per_s"], r["rows_per_s"], r["buckets"], r["route"]]
         for r in rows])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
