"""Paper §4.5 implementation-detail ablations (Corollary 5 + scaling).

Measures, on a fixed (K, C):
  1. P ⊂ S enforcement on/off            (Corollary 5 / §4.5 trick 1)
  2. scaled vs unscaled leverage rows    (§4.5 trick 2 — stability)
  3. leverage vs uniform S               (paper: 'not much difference')
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import calibrate_sigma, make_dataset, print_table
from repro.core import spsd
from repro.core.kernelop import RBFKernel


def run(dataset="pendigit", c=15, s_mult=8, trials=5, seed=0):
    X, _ = make_dataset(dataset, seed=seed)
    sigma = calibrate_sigma(X, 0.9, 3)
    Kop = RBFKernel(X, sigma=sigma)
    base = spsd.sample_C(Kop, jax.random.PRNGKey(seed), c)
    s = s_mult * c

    def err(**kw):
        es = [float(spsd.relative_error(Kop, spsd.fast_model_from_C(
            Kop, base.C, jax.random.PRNGKey(100 + i), s,
            P_indices=base.P_indices, **kw))) for i in range(trials)]
        return float(np.mean(es)), float(np.std(es))

    rows = []
    for kind in ("uniform", "leverage"):
        for subset in (True, False):
            for scale in (False, True):
                m, sd = err(s_sketch=kind, enforce_subset=subset,
                            scale=scale)
                rows.append((kind, "P⊂S" if subset else "indep",
                             "scaled" if scale else "unscaled",
                             f"{m:.5f} ± {sd:.5f}"))
    print_table(f"§4.5 ablations ({dataset}, c={c}, s={s_mult}c)",
                ["S sketch", "subset", "row scaling", "rel err"], rows)
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="pendigit")
    args = p.parse_args(argv)
    run(args.dataset)


if __name__ == "__main__":
    main()
